"""The cross-policy comparison matrix: {policy × ordering × trace scenario}.

Drives every registered assignment algorithm (obta, nlip, wf, wf_jax, rd,
rd_plus) under FIFO and prioritized-reordering scheduling across all
registered trace scenarios through the single engine API, and prints a
JCT + per-job assignment-overhead table mirroring the paper's Table 1 —
but generalized to the full policy family (Figs. 8-14 are slices of this
matrix).

Arrival bursts are admitted through the engine's batched path (one
chained device dispatch for wf_jax; an eq. 2 commit walk otherwise), and
RD/RD+ run the class-compressed implementation — together they make the
non-smoke matrix run at paper scale instead of being a smoke demo.

Usage::

    PYTHONPATH=src python -m benchmarks.policy_matrix [--smoke] \
        [--scenarios alibaba,bursty] [--orderings fifo,ocwf-acc,setf] \
        [--out policy_matrix_full.csv]
    PYTHONPATH=src python -m benchmarks.policy_matrix --waterlevel-sweep

``--smoke`` runs a reduced matrix sized for CI (~2 min on 2 CPU cores).
Detailed rows land in ``results/policy_matrix.csv`` (or ``--out``); the
nightly workflow uploads them as a tracked artifact so the JCT/overhead
table can be trended across PRs.

``--waterlevel-sweep`` instead benchmarks the water-level primitive
itself — the engine's inner loop — across M ∈ {64, 512, 4096, 16384}
servers, comparing jnp vs Pallas dispatch latency of
``water_fill_groups`` and asserting the two backends stay bit-identical.
Results land in ``results/BENCH_waterlevel.json`` (uploaded nightly next
to the matrix CSVs).  On CPU the kernel runs in interpret mode, so the
sweep tracks correctness + jnp-path latency there; the Pallas column is
only meaningful on real TPU.

``--online-sweep`` runs the open-loop serving benchmark: the bursty
trace re-timed by :func:`repro.traces.replay_client` to each QPS point
and driven through the event-stepped control plane (``step_mode=
"event"``) under a rotating-straggler timeline, sweeping QPS ×
{stealing, speculation}.  The ``plain`` cell is asserted
schedule-identical to the slot-stepped loop; results land in
``results/BENCH_online.json`` (uploaded nightly).

``--placement-churn`` runs the placement-churn scenario: the bursty
trace generated through a :class:`repro.placement.PlacementStore`, with
replica evictions and periodic rebalances injected as placement events,
swept over {replication policy × re-replication cadence}.  Metrics show
what re-replication buys under churn (JCT, failed jobs, stranded-task
reassignments); rows land in ``results/placement_churn.csv`` (uploaded
nightly).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import registry
from repro.backend import set_backend
from repro.obs import ObsSession
from repro.runtime import SchedulingEngine, list_policies, make_policy
from repro.traces import available_scenarios, generate

from .common import RESULTS_DIR, emit, summarize, write_csv

# the full ordering axis comes from the registry; the default matrix
# drops plain "ocwf" (same schedule as ocwf-acc, strictly more overhead)
DEFAULT_ORDERINGS = tuple(
    o for o in registry.names("ordering") if o != "ocwf"
)

ONLINE_QPS = (0.25, 0.5, 1.0, 2.0)
ONLINE_MODES = (  # {stealing, speculation} grid over the event loop
    ("plain", False, False),
    ("steal", True, False),
    ("spec", False, True),
    ("steal+spec", True, True),
)
# sustained-overload utilisations: more work offered per slot than the
# cluster can serve; run with admission control on, where load shedding
# keeps the event heap bounded
ONLINE_OVERLOAD_RHO = (1.1, 1.5)

WATERLEVEL_MS = (64, 512, 4096, 16384)

RD_SWEEP_MS = (64, 512, 4096, 16384)
RD_SWEEP_BURSTS = (1, 8, 64)

# re-replication cadence sweep: rebalance every N slots (0 = never)
CHURN_CADENCES = (0, 16, 4)
CHURN_EVICT_RATE = 0.3  # per-slot replica-eviction probability
# reordering policies swept at this representative cadence (the full
# cadence grid stays FIFO: reorder rescans already dominate those cells)
CHURN_ORDERINGS = ("ocwf", "ocwf-acc", "setf")
CHURN_REORDER_CADENCE = 16

CHURN_FIELDS = [
    "repl_policy",
    "ordering",
    "rebalance_every",
    "evict_rate",
    "mean_jct",
    "p99_jct",
    "failed_jobs",
    "reassigned",
    "replicas_added",
    "replicas_evicted",
    "makespan",
    "wall_s",
]

FIELDS = [
    "scenario",
    "assign",
    "ordering",
    "mean_jct",
    "p50_jct",
    "p90_jct",
    "p99_jct",
    "max_jct",
    "mean_overhead_us",
    "p99_overhead_us",
    "makespan",
    "wall_s",
]


def run_matrix(
    *,
    scenarios: tuple[str, ...],
    orderings: tuple[str, ...],
    assigners: tuple[str, ...],
    trace_kw: dict,
) -> list[dict]:
    import dataclasses

    from repro.traces import TRACES

    rows: list[dict] = []
    for scenario in scenarios:
        # keep only the knobs this scenario's config has: the CSV replay
        # (cluster_v2017) brings its own task counts, so e.g. total_tasks
        # doesn't apply there
        fields = {f.name for f in dataclasses.fields(TRACES[scenario][0])}
        jobs_kw = {k: v for k, v in trace_kw.items() if k in fields}
        n_servers = trace_kw["n_servers"]
        jobs = generate(scenario, **jobs_kw)
        for assign in assigners:
            for ordering in orderings:
                policy = make_policy(assign, ordering)
                engine = SchedulingEngine(n_servers, policy)
                t0 = time.perf_counter()
                res = engine.run(jobs)
                metrics = summarize(res, time.perf_counter() - t0)
                row = {
                    "scenario": scenario,
                    "assign": assign,
                    "ordering": ordering,
                    **{k: round(v, 3) for k, v in metrics.items()},
                }
                rows.append(row)
                emit(
                    f"matrix/{scenario}/{policy.name}",
                    metrics["mean_overhead_us"],
                    metrics["mean_jct"],
                )
    return rows


def run_waterlevel_sweep(
    ms: tuple[int, ...] = WATERLEVEL_MS,
    *,
    k_groups: int = 8,
    iters: int = 10,
    seed: int = 0,
    out_json: str = "BENCH_waterlevel.json",
) -> dict:
    """M-sweep of the water-level primitive: jnp vs Pallas dispatch.

    Each cell times the jitted ``water_fill_groups`` (K groups over M
    servers — one WF assignment, the unit of work inside every policy
    and the chained burst scan) with both backends, asserts bit-equality
    of (alloc, levels, Φ), and records median latency.  The payload is
    written to ``results/<out_json>`` so nightly CI can track the perf
    trajectory alongside the policy-matrix CSVs.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import wf_jax

    rng = np.random.default_rng(seed)
    rows: list[dict] = []
    for m in ms:
        busy = jnp.asarray(rng.integers(0, 64, m), jnp.int32)
        mu = jnp.asarray(rng.integers(1, 8, m), jnp.int32)
        gm = rng.random((k_groups, m)) < 0.5
        gm[:, 0] = True  # no empty availability sets
        gm_j = jnp.asarray(gm)
        demands = jnp.asarray(rng.integers(1, 4 * m, k_groups), jnp.int32)

        def timed(use_pallas):
            def call():
                return wf_jax._wf_groups_jit(
                    busy, mu, gm_j, demands, use_pallas=use_pallas
                )

            out = call()
            jax.block_until_ready(out)  # compile outside the timed region
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(call())
                times.append(time.perf_counter() - t0)
            return out, float(np.median(times) * 1e6)

        out_jnp, jnp_us = timed(False)
        out_pallas, pallas_us = timed(True)
        match = all(
            bool(jnp.array_equal(a, b)) for a, b in zip(out_jnp, out_pallas)
        )
        if not match:
            raise AssertionError(
                f"waterlevel sweep: Pallas != jnp at M={m} — parity broken"
            )
        rows.append(
            {
                "m": m,
                "k_groups": k_groups,
                "jnp_us": round(jnp_us, 1),
                "pallas_us": round(pallas_us, 1),
                "jnp_over_pallas": round(jnp_us / max(pallas_us, 1e-9), 3),
                "match": match,
            }
        )
        emit(f"waterlevel/m{m}/jnp", jnp_us, 0.0)
        emit(f"waterlevel/m{m}/pallas", pallas_us, jnp_us / max(pallas_us, 1e-9))
    payload = {
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "iters": iters,
        "seed": seed,
        "sweep": rows,
    }
    path = os.path.join(RESULTS_DIR, out_json)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# waterlevel sweep written to {path}", flush=True)
    return payload


def _rd_instance(rng, m, n_tasks, k_groups=8, avail=(8, 12)):
    """One synthetic RD arrival at cluster width ``m`` (paper-shaped
    availability: each group picks `avail` servers Zipf-free uniform)."""
    import numpy as np

    from repro.core import AssignmentProblem, TaskGroup
    from repro.traces.placement import normalize_sizes

    lo = min(avail[0], m)
    hi = min(avail[1], m)
    # normalize_sizes keeps Σ sizes == n_tasks exactly (a bare clamp of a
    # multinomial draw would silently grow the workload past the recorded
    # n_tasks metadata)
    sizes = normalize_sizes(rng.random(k_groups) + 0.1, n_tasks)
    groups = tuple(
        TaskGroup(
            int(s),
            tuple(
                sorted(
                    rng.choice(
                        m, size=int(rng.integers(lo, hi + 1)), replace=False
                    ).tolist()
                )
            ),
        )
        for s in sizes
    )
    return AssignmentProblem(
        busy=rng.integers(0, 40, m), mu=rng.integers(3, 6, m), groups=groups
    )


def run_rd_sweep(
    ms: tuple[int, ...] = RD_SWEEP_MS,
    bursts: tuple[int, ...] = RD_SWEEP_BURSTS,
    *,
    n_tasks: int = 192,
    iters: int = 3,
    seed: int = 0,
    out_json: str = "BENCH_rd.json",
) -> dict:
    """Per-arrival RD overhead sweep: host vs jnp vs Pallas across M,
    plus burst-admission cost across burst sizes.

    Each M cell times one RD assignment (the unit of work inside the
    ``rd``/``rd_plus`` policies) through each backend and asserts the
    assignments stay identical; the burst section times
    ``replica_deletion_batch`` — the engine's same-slot admission path —
    per job, host commit walk vs one chained device dispatch.  The
    payload lands in ``results/<out_json>`` (uploaded by nightly CI) so
    the host/device trajectory is tracked like the water-level sweep.

    On CPU the device backends are expected to *lose* (the jnp while
    loop pays per-strip XLA dispatch, and Pallas only runs in interpret
    mode — its cells use a reduced instance, recorded per-cell as
    ``n_tasks``); auto-dispatch therefore stays on host off-TPU, and the
    device columns exist to track the TPU trajectory.
    """
    import jax
    import numpy as np

    from repro.core import AssignmentProblem
    from repro.core.rd import replica_deletion, replica_deletion_batch
    from repro.core.rd_jax import replica_deletion_jax

    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(seed)

    def timed(fn, warmup=True):
        if warmup:
            out = fn()
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return out, float(np.median(times) * 1e6)

    m_rows: list[dict] = []
    for m in ms:
        prob = _rd_instance(rng, m, n_tasks)
        host, host_us = timed(lambda: replica_deletion(prob), warmup=False)
        dev, jnp_us = timed(lambda: replica_deletion_jax(prob))
        if dev.alloc != host.alloc:
            raise AssertionError(f"rd sweep: jnp != host at M={m}")
        row = {
            "m": m,
            "n_tasks": n_tasks,
            "host_us": round(host_us, 1),
            "jnp_us": round(jnp_us, 1),
            "jnp_over_host": round(jnp_us / max(host_us, 1e-9), 3),
        }
        if on_tpu:
            pal, pallas_us = timed(
                lambda: replica_deletion_jax(prob, backend="pallas")  # reprolint: disable=R007 sweep measures the kernel strip explicitly
            )
            if pal.alloc != host.alloc:
                raise AssertionError(f"rd sweep: pallas != host at M={m}")
            row["pallas_us"] = round(pallas_us, 1)
        m_rows.append(row)
        emit(f"rd/m{m}/host", host_us, 0.0)
        emit(f"rd/m{m}/jnp", jnp_us, jnp_us / max(host_us, 1e-9))

    # Pallas on CPU runs the strip kernel interpreted (pure-Python per
    # stage), so parity + latency are tracked on one reduced instance
    # instead of the full curve — the full column appears on real TPU.
    pallas_rows: list[dict] = []
    if not on_tpu:
        tiny_tasks = 24
        prob = _rd_instance(rng, ms[0], tiny_tasks, k_groups=3)
        host = replica_deletion(prob)
        pal, pallas_us = timed(
            lambda: replica_deletion_jax(prob, backend="pallas")  # reprolint: disable=R007 sweep measures the kernel strip explicitly
        )
        if pal.alloc != host.alloc:
            raise AssertionError("rd sweep: pallas(interpret) != host")
        pallas_rows.append(
            {
                "m": ms[0],
                "n_tasks": tiny_tasks,
                "interpret": True,
                "pallas_us": round(pallas_us, 1),
            }
        )
        emit(f"rd/m{ms[0]}/pallas-interpret", pallas_us, 0.0)

    burst_rows: list[dict] = []
    m_burst = ms[0]
    tasks_per_job = 16
    for nb in bursts:
        base = _rd_instance(rng, m_burst, tasks_per_job)
        probs = [base] + [
            AssignmentProblem(
                busy=base.busy,
                mu=p.mu,
                groups=p.groups,
            )
            for p in (
                _rd_instance(rng, m_burst, tasks_per_job) for _ in range(nb - 1)
            )
        ]
        with set_backend(rd="host"):
            walk, walk_us = timed(
                lambda: replica_deletion_batch(probs), warmup=False
            )
        with set_backend(rd="jnp"):
            chain, chain_us = timed(lambda: replica_deletion_batch(probs))
        if [a.alloc for a in walk] != [a.alloc for a in chain]:
            raise AssertionError(f"rd sweep: chain != walk at burst={nb}")
        burst_rows.append(
            {
                "burst": nb,
                "m": m_burst,
                "tasks_per_job": tasks_per_job,
                "host_walk_us_per_job": round(walk_us / nb, 1),
                "jnp_chain_us_per_job": round(chain_us / nb, 1),
            }
        )
        emit(f"rd/burst{nb}/host-walk", walk_us / nb, 0.0)
        emit(f"rd/burst{nb}/jnp-chain", chain_us / nb, 0.0)

    payload = {
        "backend": jax.default_backend(),
        "pallas_interpret": not on_tpu,
        "iters": iters,
        "seed": seed,
        "m_sweep": m_rows,
        "pallas_interpret_probe": pallas_rows,
        "burst_sweep": burst_rows,
    }
    path = os.path.join(RESULTS_DIR, out_json)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# rd sweep written to {path}", flush=True)
    return payload


def run_placement_churn(
    *,
    smoke: bool = False,
    cadences: tuple[int, ...] = CHURN_CADENCES,
    orderings: tuple[str, ...] = CHURN_ORDERINGS,
    evict_rate: float = CHURN_EVICT_RATE,
    out_csv: str = "placement_churn.csv",
) -> list[dict]:
    """The placement-churn scenario: {replication policy × cadence} under
    FIFO WF, plus {replication policy × reordering} at a fixed cadence.

    Every cell regenerates the bursty trace through a fresh
    ``PlacementStore`` (same seed → same initial placement for every
    policy), injects a deterministic churn timeline (per-slot replica
    evictions at ``evict_rate`` + a rebalance every ``cadence`` slots),
    and drives the engine under WF.  Evictions strand queued fragments
    through the fault path; rebalances run the store's replication
    policy — so the sweep shows what each re-replication policy buys
    back (fewer failed jobs / reassignments, lower JCT) as the cadence
    tightens.  Blocks get 2-4 initial replicas (instead of the matrix's
    8-12) so churn actually bites: losing a replica narrows an eligible
    set by 25-50% and last-replica evictions are reachable.

    The reordering rows (OCWF / OCWF-ACC / SETF at
    ``CHURN_REORDER_CADENCE``) answer the ROADMAP's open question: every
    replica add/evict changes eligible sets mid-trace, and reordering
    policies re-place the *whole* outstanding set on each arrival — so
    churn-driven locality changes are realized (or paid for) at each
    rescan rather than only at admission.  OCWF and OCWF-ACC realize the
    same schedule; their rows differ in overhead only.
    """
    from repro.placement import (
        HotBlockPolicy,
        PlacementStore,
        churn_timeline,
        list_replication_policies,
    )

    if smoke:
        trace_kw = dict(n_jobs=25, total_tasks=4_000, n_servers=25, seed=0)
    else:
        trace_kw = dict(n_jobs=120, total_tasks=40_000, n_servers=60, seed=0)
    trace_kw.update(avail_lo=2, avail_hi=4)
    n_servers = trace_kw["n_servers"]

    def churn_policy(name: str):
        """Benchmark-scaled policy instances (the class defaults target
        serve blocks with ~2 replicas, not 2-4-replica data blocks)."""
        if name == "hot-block":
            return HotBlockPolicy(max_replicas=6, min_replicas=2, add_budget=16)
        return name

    def run_cell(repl_policy: str, ordering: str, every: int) -> dict:
        store = PlacementStore(n_servers, policy=churn_policy(repl_policy))
        jobs = generate("bursty", store=store, **trace_kw)
        horizon = (
            max(j.arrival for j in jobs)
            + trace_kw["total_tasks"] // n_servers
            + 50
        )
        events = churn_timeline(
            store,
            horizon=horizon,
            rebalance_every=every,
            evict_rate=evict_rate,
            seed=trace_kw["seed"] + 1,
        )
        engine = SchedulingEngine(
            n_servers,
            make_policy("wf", ordering),
            events=events,
            placement=store,
        )
        t0 = time.perf_counter()
        res = engine.run(jobs)
        wall = time.perf_counter() - t0
        row = {
            "repl_policy": repl_policy,
            "ordering": ordering,
            "rebalance_every": every,
            "evict_rate": evict_rate,
            "mean_jct": round(res.mean_jct, 3),
            "p99_jct": round(res.jct_percentile(99), 3),
            "failed_jobs": len(res.failed_jobs),
            "reassigned": res.reassignments,
            "replicas_added": store.replicas_added,
            "replicas_evicted": store.replicas_evicted,
            "makespan": res.makespan,
            "wall_s": round(wall, 3),
        }
        emit(
            f"placement_churn/{repl_policy}/{ordering}/every{every}",
            res.mean_overhead_s * 1e6,
            res.mean_jct,
        )
        return row

    rows: list[dict] = []
    for repl_policy in list_replication_policies():
        for every in cadences:
            rows.append(run_cell(repl_policy, "fifo", every))
        for ordering in orderings:
            rows.append(run_cell(repl_policy, ordering, CHURN_REORDER_CADENCE))
    # absolute out_csv (tests hand a tmp dir) bypasses results/
    path = out_csv if os.path.isabs(out_csv) else os.path.join(RESULTS_DIR, out_csv)
    write_csv(path, rows, CHURN_FIELDS)
    print(f"# placement churn table written to {path}", flush=True)
    return rows


def run_online_sweep(
    *,
    smoke: bool = False,
    qps_points: tuple[float, ...] = ONLINE_QPS,
    out_json: str = "BENCH_online.json",
) -> dict:
    """Open-loop serving sweep: QPS × {stealing, speculation} over the
    event-stepped control plane.

    The bursty trace is re-timed by :func:`repro.traces.replay_client`
    to each QPS point and driven through ``step_mode="event"`` under WF,
    with a rotating straggler timeline (periodic 6× slowdowns) so the
    online mechanisms have something to react to.  Each QPS point runs
    the {stealing, speculation} grid; the ``plain`` cell doubles as an
    equivalence probe — it is asserted schedule-identical to the slot-
    stepped loop on the same re-timed trace.

    On top of the QPS axis, ``ONLINE_OVERLOAD_RHO`` adds sustained-
    overload points (ρ > 1) run with admission control on: those cells
    record shed counts, peak deferred-queue depth, and peak event-heap
    size, and the heap peak is asserted bounded by the submitted work
    (the whole point of shedding at ρ > 1).  The slot-loop equivalence
    probe is skipped there — admission is an event-loop-only mechanism.

    The payload lands in ``results/<out_json>`` (uploaded by nightly
    CI) with per-cell mean JCT, steal/speculation counts, overload
    accounting, and the delta vs the plain loop.
    """
    from repro.runtime import ResilienceConfig, ServerEvent
    from repro.traces import replay_client, saturation_qps

    if smoke:
        trace_kw = dict(n_jobs=25, total_tasks=4_000, n_servers=25, seed=5)
    else:
        trace_kw = dict(n_jobs=60, total_tasks=20_000, n_servers=40, seed=5)
    base = generate("bursty", **trace_kw)
    n_servers = trace_kw["n_servers"]
    # saturation point: offered load ρ = qps·E[tasks/job] / (M·E[μ]).
    # ρ→1 is where queueing explodes and P99 separates the mechanisms;
    # the plain≡slot equivalence assertion below covers this point too.
    qps_one = saturation_qps(base, n_servers)
    qps_sat = round(0.95 * qps_one, 4)
    qps_points = tuple(qps_points) + (qps_sat,)

    def rho(qps: float) -> float:
        return qps / qps_one

    # overload cells: shed early enough that the finite bench trace
    # actually exercises the defer -> shed ladder (the library defaults
    # in ResilienceConfig are sized for long-running planes)
    overload_cfg = ResilienceConfig(
        admission=True,
        lag_defer_budget=8,
        lag_shed_budget=24,
        defer_queue_cap=16,
    )
    points = [(qps, None) for qps in qps_points] + [
        (round(r * qps_one, 4), overload_cfg) for r in ONLINE_OVERLOAD_RHO
    ]
    # rotating stragglers: every 30 slots another server runs 6x slow
    # for 20 slots — the regime where idle-edge mechanisms pay off
    events = tuple(
        ServerEvent(s, "slowdown", (s // 30) % n_servers, factor=6.0)
        for s in range(10, 600, 30)
    ) + tuple(
        ServerEvent(s + 20, "speedup", (s // 30) % n_servers)
        for s in range(10, 600, 30)
    )

    rows: list[dict] = []
    for qps, res_cfg in points:
        jobs = replay_client(base, qps=qps)
        if res_cfg is None:
            slot_res = SchedulingEngine(
                n_servers, make_policy("wf"), events=events
            ).run(jobs)
        plain_jct = None
        for mode, stealing, speculation in ONLINE_MODES:
            # metrics-only session: steal/spec outcome accounting
            # (attempted / won / cancelled) without trace overhead
            cell_obs = ObsSession(trace=False, device=False)
            engine = SchedulingEngine(
                n_servers,
                make_policy("wf"),
                events=events,
                step_mode="event",
                stealing=stealing,
                speculation=speculation,
                resilience=res_cfg,
                obs=cell_obs,
            )
            t0 = time.perf_counter()
            res = engine.run(jobs)
            wall = time.perf_counter() - t0
            if mode == "plain" and res_cfg is None:
                if (
                    res.jct != slot_res.jct
                    or res.makespan != slot_res.makespan
                ):
                    raise AssertionError(
                        f"online sweep: event loop diverged from slot loop "
                        f"at qps={qps}"
                    )
            if mode == "plain":
                plain_jct = res.mean_jct
            if res_cfg is not None:
                # the bounded-heap contract shedding exists to uphold:
                # the timeline never exceeds the submitted work
                bound = len(jobs) + len(events) + 16
                if res.heap_peak > bound:
                    raise AssertionError(
                        f"online sweep: event heap peaked at "
                        f"{res.heap_peak} > bound {bound} under overload "
                        f"qps={qps}"
                    )
            row = {
                "qps": qps,
                "rho": round(rho(qps), 3),
                "mode": mode,
                "admission": res_cfg is not None,
                "mean_jct": round(res.mean_jct, 3),
                "p99_jct": round(res.jct_percentile(99), 3),
                "jct_vs_plain": round(res.mean_jct - plain_jct, 3),
                "steals": res.steals,
                "speculations": res.speculations,
                "spec_cancels": res.spec_cancels,
                # outcome accounting (obs metrics): attempts vs wins vs
                # cancellations per mechanism, per sweep point
                "steal_attempted": cell_obs.metrics.counter("steal.attempted"),
                "steal_won": cell_obs.metrics.counter("steal.won"),
                "spec_attempted": cell_obs.metrics.counter("spec.launched"),
                "spec_won": cell_obs.metrics.counter("spec.won_clone"),
                "spec_lost": cell_obs.metrics.counter("spec.won_original"),
                "spec_cancelled": cell_obs.metrics.counter("spec.aborted")
                + res.spec_cancels,
                # overload accounting (all-zero on the admission-off
                # points): dropped jobs, pending-queue high-water mark,
                # and the event-heap high-water mark the bound checks
                "shed": res.n_shed,
                "deferred_peak": res.deferred_peak,
                "heap_peak": res.heap_peak,
                "makespan": res.makespan,
                "wall_s": round(wall, 3),
            }
            rows.append(row)
            emit(f"online/qps{qps}/{mode}", wall * 1e6, res.mean_jct)
    payload = {
        "scenario": "bursty+rotating-stragglers",
        "trace_kw": trace_kw,
        "qps_points": [q for q, _ in points],
        "qps_sat": qps_sat,
        "overload_rho": list(ONLINE_OVERLOAD_RHO),
        "sweep": rows,
    }
    path = os.path.join(RESULTS_DIR, out_json)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# online sweep written to {path}", flush=True)
    return payload


def print_table(rows: list[dict], cols: list[str] | None = None) -> None:
    cols = cols or ["scenario", "assign", "ordering", "mean_jct", "p99_jct",
                    "mean_overhead_us", "makespan"]
    widths = {
        c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols
    }
    header = "  ".join(c.ljust(widths[c]) for c in cols)
    print("\n" + header)
    print("-" * len(header))
    prev_group = None
    for r in rows:
        group = r[cols[0]]
        if group != prev_group and prev_group is not None:
            print()
        prev_group = group
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true", help="CI-sized matrix")
    parser.add_argument(
        "--scenarios", default=",".join(available_scenarios()),
        help="comma-separated trace scenarios (default: every scenario "
        "that can generate here — cluster_v2017 joins when its CSV is "
        "present)",
    )
    parser.add_argument(
        "--orderings", default=",".join(DEFAULT_ORDERINGS),
        help="comma-separated orderings (fifo,ocwf,ocwf-acc,setf)",
    )
    parser.add_argument(
        "--assign", default=",".join(list_policies()),
        help="comma-separated assignment algorithms",
    )
    parser.add_argument(
        "--no-header", action="store_true",
        help="suppress the CSV header (when a caller already printed it)",
    )
    parser.add_argument(
        "--out", default="policy_matrix.csv",
        help="CSV filename under results/ (lets nightly keep the smoke and "
        "paper-scale tables side by side)",
    )
    parser.add_argument(
        "--waterlevel-sweep", action="store_true",
        help="benchmark the water-level primitive (jnp vs Pallas) across "
        "M and emit results/BENCH_waterlevel.json instead of the matrix",
    )
    parser.add_argument(
        "--rd-sweep", action="store_true",
        help="benchmark RD per-arrival overhead (host vs jnp vs Pallas) "
        "across M and burst sizes and emit results/BENCH_rd.json instead "
        "of the matrix",
    )
    parser.add_argument(
        "--online-sweep", action="store_true",
        help="run the open-loop online-serving sweep (QPS × {stealing, "
        "speculation} over the event-stepped control plane) and emit "
        "results/BENCH_online.json instead of the matrix",
    )
    parser.add_argument(
        "--placement-churn", action="store_true",
        help="run the placement-churn scenario ({replication policy × "
        "re-replication cadence} under replica evictions) and emit "
        "results/placement_churn.csv instead of the matrix",
    )
    args = parser.parse_args(argv)

    if args.waterlevel_sweep:
        if not args.no_header:
            print("name,us_per_call,derived", flush=True)
        run_waterlevel_sweep(iters=3 if args.smoke else 10)
        return

    if args.rd_sweep:
        if not args.no_header:
            print("name,us_per_call,derived", flush=True)
        if args.smoke:
            run_rd_sweep(
                ms=(64, 512), bursts=(1, 8), n_tasks=64, iters=2
            )
        else:
            run_rd_sweep()
        return

    if args.online_sweep:
        if not args.no_header:
            print("name,us_per_call,derived", flush=True)
        payload = run_online_sweep(smoke=args.smoke)
        print_table(
            payload["sweep"],
            ["qps", "rho", "mode", "mean_jct", "p99_jct", "jct_vs_plain",
             "steals", "speculations", "makespan"],
        )
        return

    if args.placement_churn:
        if not args.no_header:
            print("name,us_per_call,derived", flush=True)
        rows = run_placement_churn(smoke=args.smoke)
        print_table(
            rows,
            ["repl_policy", "ordering", "rebalance_every", "mean_jct",
             "p99_jct", "failed_jobs", "reassigned", "replicas_added",
             "makespan"],
        )
        return

    if args.smoke:
        trace_kw = dict(n_jobs=25, total_tasks=4_000, n_servers=25, seed=0)
    else:
        trace_kw = dict(n_jobs=120, total_tasks=40_000, n_servers=60, seed=0)

    t0 = time.time()
    if not args.no_header:
        print("name,us_per_call,derived", flush=True)
    rows = run_matrix(
        scenarios=tuple(args.scenarios.split(",")),
        orderings=tuple(args.orderings.split(",")),
        assigners=tuple(args.assign.split(",")),
        trace_kw=trace_kw,
    )
    write_csv(os.path.join(RESULTS_DIR, args.out), rows, FIELDS)
    print_table(rows)
    print(f"# matrix wall time: {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
