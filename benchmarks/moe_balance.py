"""Framework bench: the paper's WF applied to MoE expert-replica routing.

Serving-time scenario (DESIGN.md §2): experts are replicated across
devices (replicas = the paper's data-chunk copies); token groups that
picked the same expert set = task groups; per-device queued tokens = busy
times.  The on-device vectorized water-filling (:mod:`repro.core.wf_jax`)
chooses which replica serves which tokens.

Compares max per-device queue (the step-completion proxy) for:
  - ``static``: every group goes to its expert's first replica;
  - ``random``: uniform random replica per group;
  - ``greedy``: least-loaded replica at decision time (token-sequential);
  - ``wf``: the paper's water-filling (jit-compiled, runs on device).

Emits ``moe/<policy>`` rows: us_per_call = routing decision time,
derived = max device queue after routing (lower is better).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wf_jax import water_fill_groups

from .common import emit


def _scenario(
    n_devices: int, n_experts: int, replicas: int, n_groups: int, seed: int
):
    rng = np.random.default_rng(seed)
    # expert e lives on `replicas` distinct devices
    placement = np.stack(
        [rng.choice(n_devices, size=replicas, replace=False) for _ in range(n_experts)]
    )
    # token groups: group g wants expert e_g with d_g tokens (Zipf-ish load)
    experts = rng.zipf(1.3, size=n_groups) % n_experts
    demands = rng.integers(16, 512, size=n_groups)
    busy0 = rng.integers(0, 64, size=n_devices)  # pre-existing queues
    group_mask = np.zeros((n_groups, n_devices), dtype=bool)
    for g in range(n_groups):
        group_mask[g, placement[experts[g]]] = True
    return busy0, group_mask, demands


def run(quick: bool = False) -> None:
    n_devices, n_experts, replicas = (16, 32, 4) if quick else (64, 128, 4)
    n_groups = 64 if quick else 256
    mu = np.ones(n_devices, dtype=np.int32)  # tokens/step per device (uniform)

    wf = jax.jit(water_fill_groups)
    results: dict[str, list[float]] = {p: [] for p in ("static", "random", "greedy", "wf")}
    times: dict[str, list[float]] = {p: [] for p in results}
    for seed in range(3):
        busy0, group_mask, demands = _scenario(
            n_devices, n_experts, replicas, n_groups, seed
        )
        rng = np.random.default_rng(seed + 100)

        # static / random / greedy baselines (host logic)
        for policy in ("static", "random", "greedy"):
            q = busy0.astype(np.int64).copy()
            t0 = time.perf_counter()
            for g in range(n_groups):
                devs = np.flatnonzero(group_mask[g])
                if policy == "static":
                    d = devs[0]
                elif policy == "random":
                    d = rng.choice(devs)
                else:  # greedy: least-loaded replica
                    d = devs[np.argmin(q[devs])]
                q[d] += demands[g]
            times[policy].append(time.perf_counter() - t0)
            results[policy].append(float(q.max()))

        # the paper's WF, vectorized on device
        args = (
            jnp.asarray(busy0, jnp.int32),
            jnp.asarray(mu),
            jnp.asarray(group_mask),
            jnp.asarray(demands, jnp.int32),
        )
        alloc, _, _ = wf(*args)  # warm-up compile
        jax.block_until_ready(alloc)
        t0 = time.perf_counter()
        alloc, _, phi = wf(*args)
        jax.block_until_ready(alloc)
        times["wf"].append(time.perf_counter() - t0)
        q = busy0 + np.asarray(alloc).sum(axis=0)
        results["wf"].append(float(q.max()))

    for policy in results:
        emit(
            f"moe/{policy}",
            float(np.mean(times[policy])) * 1e6,
            float(np.mean(results[policy])),
        )


if __name__ == "__main__":
    run()
